"""Run one train step + one serve step on EVERY assigned architecture's
smoke variant — the 10-architecture support matrix in one script.

    PYTHONPATH=src python examples/multiarch_smoke.py
"""
import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models import model as M


def main():
    key = jax.random.PRNGKey(0)
    print(f"{'arch':20s} {'type':7s} {'loss':>8s} {'decode ok':>9s}")
    for arch in ASSIGNED_ARCHS:
        cfg = get_smoke_config(arch)
        params = M.init_params(cfg, key, jnp.float32)
        B, S = 2, 64
        batch = {"tokens": jnp.full((B, S), 3, jnp.int32),
                 "labels": jnp.ones((B, S), jnp.int32)}
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.ones((B, 32, cfg.d_model), jnp.float32) * .01
        if cfg.frontend == "vit_patch_stub":
            batch["patch_embeds"] = jnp.ones(
                (B, cfg.num_patches, cfg.d_model), jnp.float32) * .01
        loss, _ = M.forward_train(params, cfg, batch, remat=False)
        extra = cfg.num_patches if cfg.frontend == "vit_patch_stub" else 0
        nb = (S + extra) // cfg.dsa.block_size + 2
        _, state = M.prefill(params, cfg, batch, nb, cache_dtype=jnp.float32)
        lg, _ = M.decode_step(params, cfg, jnp.array([5, 7], jnp.int32), state)
        ok = bool(jnp.all(jnp.isfinite(lg)))
        print(f"{arch:20s} {cfg.arch_type:7s} {float(loss):8.4f} {str(ok):>9s}")


if __name__ == "__main__":
    main()
