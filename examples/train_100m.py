"""Train a ~100M-parameter qwen2-family model for a few hundred steps on
the synthetic token pipeline (deliverable b: end-to-end train driver).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

The config is a width/depth-reduced qwen2 (~100M params with the full
151936 vocab) — the same model definition the dry-run lowers at full scale.
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models.common import ModelConfig
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import TrainConfig, train


def config_100m() -> ModelConfig:
    base = get_config("qwen2-0.5b")
    return dataclasses.replace(base, name="qwen2-100m",
                               num_layers=4, d_model=512, num_heads=8,
                               num_kv_heads=2, d_ff=2048, vocab_size=32768)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m.npz")
    args = ap.parse_args()

    cfg = config_100m()
    n = cfg.param_count()
    print(f"training {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, seed=0)
    tc = TrainConfig(
        steps=args.steps, log_every=max(args.steps // 20, 1),
        ckpt_path=args.ckpt,
        opt=AdamWConfig(lr=3e-4, warmup_steps=args.steps // 10,
                        total_steps=args.steps))
    params, hist = train(cfg, tc, dc)
    print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"(ckpt: {args.ckpt})")
    assert hist["loss"][-1] < hist["loss"][0]


if __name__ == "__main__":
    main()
